#!/usr/bin/env python3
"""repro_lint — repo-specific JAX-purity static analysis (AST-based).

Nine PRs of jit/shard_map/Pallas/cache machinery accumulate a specific
class of hazards that generic linters cannot see: host-side control flow
on traced values, numpy leaking into jitted bodies, impure reads under
``jit``, identity-unstable cache keys, and benchmark timers that measure
dispatch instead of compute. Each is a pluggable :class:`LintRule`
visitor over one file's AST:

=======  ==================================================================
rule     meaning
=======  ==================================================================
RL001    Python-level branch (``if``/``while``/``assert``) on a traced
         parameter inside a jitted or Pallas body — trace-time constanting
         or a ConcretizationTypeError at best, silent specialisation at
         worst. Parameters named in ``static_argnames`` are exempt.
RL002    ``np.*`` call on a traced parameter inside a jitted/Pallas body —
         numpy escapes the trace and forces host sync (np on *constants*
         at trace time is fine and not flagged).
RL003    unseeded ``np.random.default_rng()`` — irreproducible randomness
         in a repo whose contracts are bitwise.
RL004    environment read (``os.environ`` / ``os.getenv``) inside a
         jitted/Pallas body — the first trace bakes the value into the
         compiled program; later env changes are silently ignored.
RL005    ``id(...)`` in a cache-key expression of a module-level cache —
         ids recycle after garbage collection, so an identity-keyed cache
         must provably retain the keyed objects (suppress with a
         justification where it does).
RL006    a benchmark ``Timer`` block that dispatches device work but never
         calls ``sync``/``block_until_ready`` before the timer stops —
         JAX dispatch is async, so the block times enqueue, not compute.
=======  ==================================================================

Suppression: append ``# repro-lint: disable=RL002`` to the offending line
(or put it on a standalone comment line directly above); several ids may
be comma-separated. Run: ``python tools/repro_lint.py [paths...]``
(default ``src benchmarks``); exits 1 on any unsuppressed finding.

Uses only the stdlib — CI runs it before installing jax.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import re
import sys
from pathlib import Path

SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*disable=([A-Za-z0-9,\s]+)")

# modules whose names dispatch device work when called (RL006)
DEVICE_MODULES = ("jax", "repro.core.jax_evaluator", "repro.kernels")
SYNC_NAMES = ("sync", "block_until_ready")


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    col: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


# --------------------------------------------------------------------------
# Shared file context: imports, alias resolution, jitted-function discovery
# --------------------------------------------------------------------------


def dotted_name(node: ast.AST) -> "str | None":
    """``jax.numpy.where`` -> "jax.numpy.where"; None for non-name chains."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class FileContext:
    """Per-file pre-pass shared by every rule: the import alias map, the
    set of jitted/Pallas function definitions (with their static
    parameter names), and the suppression map."""

    def __init__(self, path: Path, source: str, tree: ast.Module):
        self.path = path
        self.tree = tree
        self.aliases: dict[str, str] = {}       # local name -> dotted module
        self.from_imports: dict[str, str] = {}  # local name -> module.attr
        self.suppressions = self._parse_suppressions(source)
        self._collect_imports(tree)
        # name -> static parameter names of the jit wrapper (empty = none)
        self.jitted: dict[str, frozenset] = {}
        self.pallas_kernels: set[str] = set()
        self._collect_jitted(tree)
        self.functions: dict[str, list[ast.FunctionDef]] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.functions.setdefault(node.name, []).append(node)

    @staticmethod
    def _parse_suppressions(source: str) -> "dict[int, set[str]]":
        """line number -> suppressed rule ids. A suppression on a
        standalone comment line also covers the next line."""
        out: dict[int, set[str]] = {}
        for i, line in enumerate(source.splitlines(), start=1):
            m = SUPPRESS_RE.search(line)
            if not m:
                continue
            rules = {r.strip().upper() for r in m.group(1).split(",")
                     if r.strip()}
            out.setdefault(i, set()).update(rules)
            if line.lstrip().startswith("#"):       # standalone comment
                out.setdefault(i + 1, set()).update(rules)
        return out

    def _collect_imports(self, tree: ast.Module) -> None:
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.aliases[a.asname or a.name.split(".")[0]] = \
                        a.name if a.asname else a.name.split(".")[0]
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.from_imports[a.asname or a.name] = \
                        f"{node.module}.{a.name}"

    def resolve(self, node: ast.AST) -> "str | None":
        """Resolve a name/attribute chain to its fully-qualified dotted
        form through the import aliases (``jnp.where`` ->
        ``jax.numpy.where``, ``jit`` -> ``jax.jit``)."""
        name = dotted_name(node)
        if name is None:
            return None
        root, _, rest = name.partition(".")
        if root in self.aliases:
            base = self.aliases[root]
            return f"{base}.{rest}" if rest else base
        if root in self.from_imports:
            base = self.from_imports[root]
            return f"{base}.{rest}" if rest else base
        return name

    # -- jit / pallas discovery ---------------------------------------------

    def _is_jit(self, node: ast.AST) -> bool:
        return self.resolve(node) in ("jax.jit", "jit")

    @staticmethod
    def _static_names(keywords) -> frozenset:
        for kw in keywords:
            if kw.arg in ("static_argnames", "static_argnums") \
                    and isinstance(kw.value, (ast.Tuple, ast.List)):
                return frozenset(
                    e.value for e in kw.value.elts
                    if isinstance(e, ast.Constant) and isinstance(e.value, str))
            if kw.arg == "static_argnames" and isinstance(kw.value, ast.Constant):
                return frozenset([kw.value.value])
        return frozenset()

    def _jit_wrapper_statics(self, call: ast.Call) -> "frozenset | None":
        """``partial(jax.jit, static_argnames=...)`` / ``jax.jit`` as a
        callable being applied -> its static names, else None."""
        if self._is_jit(call.func):
            return self._static_names(call.keywords)
        if isinstance(call.func, ast.Call) \
                and self.resolve(call.func.func) in ("functools.partial",
                                                     "partial") \
                and call.func.args and self._is_jit(call.func.args[0]):
            return self._static_names(call.func.keywords)
        return None

    def _mark(self, node: ast.AST, statics: frozenset) -> None:
        """Mark the function a jit/pallas wrapper call is applied to;
        unwraps ``shard_map(body, ...)`` / ``vmap(f)`` one level."""
        if isinstance(node, ast.Call) and node.args \
                and self.resolve(node.func) is not None \
                and self.resolve(node.func).split(".")[-1] in (
                    "shard_map", "vmap", "pmap", "checkpoint", "remat"):
            node = node.args[0]
        if isinstance(node, ast.Name):
            self.jitted[node.id] = statics

    def _collect_jitted(self, tree: ast.Module) -> None:
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    if self._is_jit(dec):
                        self.jitted[node.name] = frozenset()
                    elif isinstance(dec, ast.Call):
                        statics = self._jit_wrapper_statics(dec)
                        if statics is not None:
                            self.jitted[node.name] = statics
            elif isinstance(node, ast.Call):
                statics = self._jit_wrapper_statics(node)
                if statics is not None and node.args:
                    self._mark(node.args[0], statics)
                resolved = self.resolve(node.func)
                if resolved and resolved.split(".")[-1] == "pallas_call" \
                        and node.args and isinstance(node.args[0], ast.Name):
                    self.pallas_kernels.add(node.args[0].id)

    def jitted_defs(self):
        """Yield (FunctionDef, non-static traced parameter names) for every
        function identified as a jit target or Pallas kernel body."""
        for name, statics in self.jitted.items():
            for fn in self.functions.get(name, []):
                args = fn.args
                params = [a.arg for a in (args.posonlyargs + args.args
                                          + args.kwonlyargs)]
                yield fn, frozenset(p for p in params if p not in statics)
        for name in self.pallas_kernels:
            for fn in self.functions.get(name, []):
                args = fn.args
                yield fn, frozenset(a.arg for a in (args.posonlyargs
                                                    + args.args
                                                    + args.kwonlyargs))


def _names_in(node: ast.AST) -> set:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


# --------------------------------------------------------------------------
# Rules
# --------------------------------------------------------------------------


class LintRule:
    """One pluggable check. Subclasses set ``rule_id``/``description`` and
    implement ``check(ctx) -> list[Finding]``."""

    rule_id = "RL000"
    description = ""

    def check(self, ctx: FileContext) -> "list[Finding]":
        raise NotImplementedError

    def finding(self, ctx: FileContext, node: ast.AST,
                message: str) -> Finding:
        return Finding(str(ctx.path), getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0), self.rule_id, message)


class TracedBranchRule(LintRule):
    rule_id = "RL001"
    description = ("Python-level if/while/assert on a traced parameter "
                   "inside a jitted or Pallas body")

    def check(self, ctx: FileContext) -> "list[Finding]":
        out = []
        for fn, traced in ctx.jitted_defs():
            for node in ast.walk(fn):
                if isinstance(node, (ast.If, ast.While)):
                    hit = _names_in(node.test) & traced
                elif isinstance(node, ast.Assert):
                    hit = _names_in(node.test) & traced
                else:
                    continue
                if hit:
                    kind = type(node).__name__.lower()
                    out.append(self.finding(
                        ctx, node,
                        f"`{kind}` on traced value(s) {sorted(hit)} inside "
                        f"jitted body `{fn.name}` — use lax.cond/jnp.where "
                        "or mark the argument static"))
        return out


class NumpyInJitRule(LintRule):
    rule_id = "RL002"
    description = ("np.* call applied to a traced parameter inside a "
                   "jitted or Pallas body")

    def check(self, ctx: FileContext) -> "list[Finding]":
        out = []
        for fn, traced in ctx.jitted_defs():
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                resolved = ctx.resolve(node.func)
                if not resolved or not (resolved == "numpy"
                                        or resolved.startswith("numpy.")):
                    continue
                arg_names: set = set()
                for a in list(node.args) + [kw.value for kw in node.keywords]:
                    arg_names |= _names_in(a)
                hit = arg_names & traced
                if hit:
                    out.append(self.finding(
                        ctx, node,
                        f"numpy call `{dotted_name(node.func)}` on traced "
                        f"value(s) {sorted(hit)} inside jitted body "
                        f"`{fn.name}` — use jnp, or hoist to the host side"))
        return out


class UnseededRngRule(LintRule):
    rule_id = "RL003"
    description = "unseeded np.random.default_rng() (irreproducible)"

    def check(self, ctx: FileContext) -> "list[Finding]":
        out = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call) \
                    and ctx.resolve(node.func) == "numpy.random.default_rng" \
                    and not node.args and not node.keywords:
                out.append(self.finding(
                    ctx, node,
                    "unseeded default_rng() — every rng in this repo must "
                    "be seeded (bitwise-reproducibility contracts)"))
        return out


class EnvReadInJitRule(LintRule):
    rule_id = "RL004"
    description = "environment read inside a jitted or Pallas body"

    def check(self, ctx: FileContext) -> "list[Finding]":
        out = []
        for fn, _traced in ctx.jitted_defs():
            for node in ast.walk(fn):
                resolved = None
                if isinstance(node, ast.Call):
                    resolved = ctx.resolve(node.func)
                elif isinstance(node, ast.Subscript):
                    resolved = ctx.resolve(node.value)
                if resolved in ("os.getenv", "os.environ.get", "os.environ"):
                    out.append(self.finding(
                        ctx, node,
                        f"environment read inside jitted body `{fn.name}` — "
                        "the first trace bakes the value in; resolve env "
                        "config before dispatch"))
        return out


class IdentityCacheKeyRule(LintRule):
    rule_id = "RL005"
    description = ("id(...) used as (part of) a cache key of a "
                   "module-level cache")

    CACHE_RE = re.compile(r"(^_|_)?CACHE", re.IGNORECASE)

    def check(self, ctx: FileContext) -> "list[Finding]":
        out = []
        for fns in ctx.functions.values():
            for fn in fns:
                names = _names_in(fn)
                touches_cache = any("CACHE" in n.upper() for n in names)
                if not touches_cache:
                    continue
                for node in ast.walk(fn):
                    if isinstance(node, ast.Call) \
                            and isinstance(node.func, ast.Name) \
                            and node.func.id == "id":
                        out.append(self.finding(
                            ctx, node,
                            f"id(...) feeds a cache key in `{fn.name}` — "
                            "ids recycle after gc; the cache must retain "
                            "the keyed objects (suppress with the retention "
                            "argument if it provably does)"))
        return out


class TimerWithoutSyncRule(LintRule):
    rule_id = "RL006"
    description = ("benchmark Timer block dispatching device work without "
                   "sync/block_until_ready")

    def _is_device_call(self, ctx: FileContext, node: ast.Call) -> bool:
        resolved = ctx.resolve(node.func)
        if not resolved:
            return False
        return any(resolved == m or resolved.startswith(m + ".")
                   for m in DEVICE_MODULES)

    def check(self, ctx: FileContext) -> "list[Finding]":
        out = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.With):
                continue
            is_timer = any(
                isinstance(item.context_expr, ast.Call)
                and (ctx.resolve(item.context_expr.func) or "").split(".")[-1]
                == "Timer" for item in node.items)
            if not is_timer:
                continue
            calls = [n for b in node.body for n in ast.walk(b)
                     if isinstance(n, ast.Call)]
            has_sync = any(
                (ctx.resolve(c.func) or "").split(".")[-1] in SYNC_NAMES
                for c in calls)
            device = [c for c in calls if self._is_device_call(ctx, c)]
            if device and not has_sync:
                out.append(self.finding(
                    ctx, node,
                    "Timer block dispatches device work "
                    f"(`{dotted_name(device[0].func)}`) but never syncs — "
                    "end the timed region with common.sync(...) on its "
                    "final results"))
        return out


RULES: "list[LintRule]" = [
    TracedBranchRule(), NumpyInJitRule(), UnseededRngRule(),
    EnvReadInJitRule(), IdentityCacheKeyRule(), TimerWithoutSyncRule(),
]


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------


def lint_file(path: Path) -> "tuple[list[Finding], list[Finding]]":
    """Returns (active findings, suppressed findings)."""
    source = path.read_text()
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError as e:
        return [Finding(str(path), e.lineno or 1, e.offset or 0, "RL000",
                        f"syntax error: {e.msg}")], []
    ctx = FileContext(path, source, tree)
    active: list[Finding] = []
    suppressed: list[Finding] = []
    for rule in RULES:
        for f in rule.check(ctx):
            if f.rule in ctx.suppressions.get(f.line, set()):
                suppressed.append(f)
            else:
                active.append(f)
    return active, suppressed


def iter_py_files(paths: "list[str]"):
    for p in paths:
        path = Path(p)
        if path.is_dir():
            yield from sorted(path.rglob("*.py"))
        elif path.suffix == ".py":
            yield path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="repo-specific JAX-purity lint (rules RL001-RL006)")
    ap.add_argument("paths", nargs="*", default=["src", "benchmarks"],
                    help="files or directories (default: src benchmarks)")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print findings silenced by disable comments")
    args = ap.parse_args(argv)
    if args.list_rules:
        for r in RULES:
            print(f"{r.rule_id}  {r.description}")
        return 0
    n_files = 0
    active: list[Finding] = []
    suppressed: list[Finding] = []
    for path in iter_py_files(args.paths or ["src", "benchmarks"]):
        n_files += 1
        a, s = lint_file(path)
        active.extend(a)
        suppressed.extend(s)
    for f in active:
        print(f)
    if args.show_suppressed:
        for f in suppressed:
            print(f"{f} [suppressed]")
    status = "FAILED" if active else "ok"
    print(f"repro-lint: {n_files} files, {len(active)} findings "
          f"({len(suppressed)} suppressed) — {status}", file=sys.stderr)
    return 1 if active else 0


if __name__ == "__main__":
    raise SystemExit(main())
